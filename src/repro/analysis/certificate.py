"""Machine-readable plan certificates + offline plan snapshots.

A :class:`PlanCertificate` packages one :func:`~repro.analysis.dataflow.
interpret_tables` run — checks run, violations found, the replayed
buffer-occupancy proofs behind the declared liveness windows, and the
exposed/hidden hop accounting — as a JSON document that CI (or an
operator) can archive next to a deployed plan and re-verify offline.

``export_plan`` / ``load_plan`` snapshot the lowered step tables
themselves (plus the skip-consumer map and pipeline config the proof is
conditional on) to a JSON file, so ``python -m repro.analysis.verify
--plan saved.json`` can re-certify a plan with no model code, no jax, and
no scheduler in the loop.  Everything in this module is numpy-only.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import numpy as np

from repro.analysis.dataflow import (CHECKS, DataflowReport, Violation,
                                     interpret_tables)

CERTIFICATE_SCHEMA = "repro.plan-certificate/v1"
PLAN_SCHEMA = "repro.saved-plan/v1"

# Mirrors runtime.pipeline.WIRE_DTYPES (asserted equal in the test
# suite); duplicated so certification never imports the jax-backed
# runtime modules.
WIRE_DTYPES = ("bfloat16", "float32")

_TABLE_FIELDS = (
    "D", "M", "V", "rings", "forward_steps", "sel", "slot", "mb",
    "down_mb", "down_valid", "up_mb", "up_valid", "loss", "embed",
    "turn_rd", "turn_wr", "down_send", "up_send", "down_slot", "up_slot",
    "rx_slot", "turn_wr_slot", "turn_rd_slot", "skip_wr", "skip_wr_slot",
    "skip_rd_slot", "W_down", "W_up", "W_turn", "W_skip", "exposed_down",
    "exposed_up", "embed_device", "turn_device")
_INT_FIELDS = ("D", "M", "V", "rings", "W_down", "W_up", "W_turn",
               "W_skip", "exposed_down", "exposed_up", "embed_device",
               "turn_device")
_BOOL_TABLES = ("down_valid", "up_valid", "loss", "embed", "turn_rd",
                "turn_wr", "down_send", "up_send", "skip_wr")


@dataclasses.dataclass(frozen=True)
class PlanCertificate:
    """The static proof for one lowered plan, serializable to JSON.

    ``ok`` is the verdict; everything else is the evidence: which checks
    ran, what failed, the replayed per-channel peak occupancies vs the
    windows the lowering declared, and the hop-overlap accounting.
    """

    ok: bool
    checks: tuple[str, ...]
    failed_checks: tuple[str, ...]
    violations: tuple[str, ...]
    plan: dict[str, Any]          # D, M, V, rings, steps, overlap, wire
    windows: dict[str, dict[str, int]]   # chan -> {declared, peak}
    hops: dict[str, int]
    name: str | None = None

    def to_dict(self) -> dict[str, Any]:
        return {"schema": CERTIFICATE_SCHEMA,
                "name": self.name,
                "ok": self.ok,
                "checks": list(self.checks),
                "failed_checks": list(self.failed_checks),
                "violations": list(self.violations),
                "plan": dict(self.plan),
                "windows": {k: dict(v) for k, v in self.windows.items()},
                "hops": dict(self.hops)}

    def to_json(self, *, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, doc: dict[str, Any]) -> "PlanCertificate":
        if doc.get("schema") != CERTIFICATE_SCHEMA:
            raise ValueError(
                f"not a plan certificate (schema={doc.get('schema')!r}, "
                f"expected {CERTIFICATE_SCHEMA!r})")
        return cls(ok=bool(doc["ok"]),
                   checks=tuple(doc["checks"]),
                   failed_checks=tuple(doc["failed_checks"]),
                   violations=tuple(doc["violations"]),
                   plan=dict(doc["plan"]),
                   windows={k: dict(v)
                            for k, v in doc["windows"].items()},
                   hops=dict(doc["hops"]),
                   name=doc.get("name"))

    @classmethod
    def from_json(cls, text: str) -> "PlanCertificate":
        return cls.from_dict(json.loads(text))

    def summary(self) -> str:
        p = self.plan
        dp, zs = p.get("dp", 1), p.get("zero_stage", 0)
        hybrid = f" dp={dp} zero={zs}" if dp > 1 or zs > 0 else ""
        head = (f"{self.name or 'plan'}: D={p['D']} M={p['M']} "
                f"V={p['V']} rings={p['rings']} steps={p['num_steps']}"
                f"{hybrid} wire={p['wire_dtype']} "
                f"{'overlap' if p['overlap'] else 'sync'}")
        win = " ".join(f"{c}={w['peak']}/{w['declared']}"
                       for c, w in self.windows.items())
        hop = (f"hops live={self.hops['live_down']}+{self.hops['live_up']} "
               f"exposed={self.hops['exposed']} "
               f"hidden={self.hops['hidden']}")
        if self.ok:
            return f"OK   {head} | peaks {win} | {hop}"
        lines = [f"FAIL {head} | checks failed: "
                 f"{', '.join(self.failed_checks)}"]
        lines += [f"  - {v}" for v in self.violations[:20]]
        if len(self.violations) > 20:
            lines.append(f"  ... and {len(self.violations) - 20} more")
        return "\n".join(lines)


def _certificate_from_report(tabs, report: DataflowReport, *,
                             overlap: bool, wire_dtype: str,
                             dp: int = 1, zero_stage: int = 0,
                             fingerprint: str | None = None,
                             name: str | None) -> PlanCertificate:
    violations = list(report.violations)
    if wire_dtype not in WIRE_DTYPES:
        violations.append(Violation(
            "wire-dtype-flow",
            f"unknown wire_dtype {wire_dtype!r}; expected one of "
            f"{WIRE_DTYPES}"))
    live_down, live_up = (int(x) for x in tabs.live_hops)
    failed = tuple(sorted({v.check for v in violations},
                          key=CHECKS.index))
    return PlanCertificate(
        ok=not violations,
        checks=CHECKS,
        failed_checks=failed,
        violations=tuple(str(v) for v in violations),
        plan={"D": int(tabs.D), "M": int(tabs.M), "V": int(tabs.V),
              "rings": int(tabs.rings),
              "num_steps": int(tabs.num_steps),
              "dp": int(dp), "zero_stage": int(zero_stage),
              "overlap": bool(overlap), "wire_dtype": wire_dtype,
              # the state-layout digest restore checks against (None for
              # bare-table certifications with no CompiledPipeline)
              "fingerprint": fingerprint},
        windows={"down": {"declared": int(tabs.W_down),
                          "peak": report.peak_down},
                 "up": {"declared": int(tabs.W_up),
                        "peak": report.peak_up},
                 "turn": {"declared": int(tabs.W_turn),
                          "peak": report.peak_turn},
                 "skip": {"declared": int(tabs.W_skip),
                          "peak": report.peak_skip}},
        hops={"live_down": live_down, "live_up": live_up,
              "exposed": report.exposed_down + report.exposed_up,
              "hidden": live_down + live_up
              - report.exposed_down - report.exposed_up,
              "dense": int(tabs.rings) * int(tabs.D)
              * int(tabs.num_steps)},
        name=name)


def certify_tables(tabs, *, skip_consumers=None, overlap: bool = True,
                   wire_dtype: str = "bfloat16",
                   dp: int = 1, zero_stage: int = 0,
                   fingerprint: str | None = None,
                   name: str | None = None) -> PlanCertificate:
    """Certify lowered step tables directly (numpy-only, no jax).

    ``skip_consumers`` must be the same consumer map the lowering was
    given (``StageLayout.skip_consumers()``) — folded V > 1 plans elide
    dead stash stores, so the conservative read-every-slot default would
    reject valid plans.  ``dp``/``zero_stage`` record the hybrid plan
    dimensions (DP replica count over the data axes and ZeRO sharding
    stage) the executor was configured with — the dataflow proof itself
    is per-replica, so they are certificate metadata, not checked state.
    """
    report = interpret_tables(tabs, overlap=overlap,
                              skip_consumers=skip_consumers)
    return _certificate_from_report(tabs, report, overlap=overlap,
                                    wire_dtype=wire_dtype, dp=dp,
                                    zero_stage=zero_stage,
                                    fingerprint=fingerprint, name=name)


def certify_plan(plan, *, name: str | None = None) -> PlanCertificate:
    """Certify a :class:`~repro.runtime.compile.CompiledPipeline`.

    Pulls the memoized lowering, consumer map, and pipeline config off
    the plan so the certificate describes exactly what ``build()`` would
    execute.  Only meaningful for the table executors — the closed-form
    differential references don't lower to step tables (their certificate
    covers what ``executor="table"`` would run for the same schedule).
    """
    tabs = plan.step_tables()
    consumers = plan.layout.skip_consumers() if plan.folded else None
    fp = plan.fingerprint() if hasattr(plan, "fingerprint") else None
    return certify_tables(
        tabs, skip_consumers=consumers, overlap=plan.pcfg.overlap,
        wire_dtype=plan.pcfg.wire_dtype,
        dp=getattr(plan.pcfg, "dp_size", 1),
        zero_stage=getattr(plan.pcfg, "zero_stage", 0),
        fingerprint=fp, name=name)


def certify_schedule(sched, *, folded: bool, devices=None,
                     skip_consumers=None, overlap: bool = True,
                     wire_dtype: str = "bfloat16",
                     dp: int = 1, zero_stage: int = 0,
                     name: str | None = None) -> PlanCertificate:
    """Lower a validated schedule and certify the result.

    Imports the (jax-backed) lowering lazily — the rest of this package
    stays importable without jax.
    """
    from repro.runtime.schedule_exec import StepTables
    tabs = StepTables.from_schedule(sched, folded=folded, devices=devices,
                                    skip_consumers=skip_consumers)
    return certify_tables(tabs, skip_consumers=skip_consumers,
                          overlap=overlap, wire_dtype=wire_dtype,
                          dp=dp, zero_stage=zero_stage, name=name)


# ===========================================================================
# Offline plan snapshots
# ===========================================================================

@dataclasses.dataclass
class SavedPlan:
    """A lowered plan snapshot: duck-typed step tables + the config the
    dataflow proof is conditional on."""

    tables: Any                   # StepTables-shaped namespace
    skip_consumers: tuple | None
    overlap: bool
    wire_dtype: str
    dp: int = 1
    zero_stage: int = 0
    name: str | None = None

    def certify(self) -> PlanCertificate:
        return certify_tables(
            self.tables, skip_consumers=self.skip_consumers,
            overlap=self.overlap, wire_dtype=self.wire_dtype,
            dp=self.dp, zero_stage=self.zero_stage,
            name=self.name)


class _Tables:
    """Plain attribute bag quacking like StepTables for the interpreter."""

    @property
    def num_steps(self) -> int:
        return self.sel.shape[1]

    @property
    def live_hops(self) -> tuple[int, int]:
        return int(self.down_send.sum()), int(self.up_send.sum())


def export_plan(tabs, path, *, skip_consumers=None, overlap: bool = True,
                wire_dtype: str = "bfloat16",
                dp: int = 1, zero_stage: int = 0,
                name: str | None = None) -> None:
    """Snapshot lowered step tables (+ proof context) to a JSON file."""
    doc: dict[str, Any] = {"schema": PLAN_SCHEMA, "name": name,
                           "overlap": bool(overlap),
                           "wire_dtype": wire_dtype,
                           "dp": int(dp), "zero_stage": int(zero_stage),
                           "skip_consumers": skip_consumers,
                           "tables": {}}
    for field in _TABLE_FIELDS:
        val = getattr(tabs, field)
        doc["tables"][field] = (np.asarray(val).tolist()
                                if isinstance(val, np.ndarray)
                                else (list(val) if isinstance(val, tuple)
                                      else int(val)))
    with open(path, "w") as fh:
        json.dump(doc, fh, sort_keys=True)


def load_plan(path) -> SavedPlan:
    """Rehydrate an :func:`export_plan` snapshot for re-certification."""
    with open(path) as fh:
        doc = json.load(fh)
    if doc.get("schema") != PLAN_SCHEMA:
        raise ValueError(
            f"not a saved plan (schema={doc.get('schema')!r}, expected "
            f"{PLAN_SCHEMA!r})")
    tabs = _Tables()
    for field in _TABLE_FIELDS:
        val = doc["tables"][field]
        if field in _INT_FIELDS:
            setattr(tabs, field, int(val))
        elif field == "forward_steps":
            setattr(tabs, field, tuple(int(x) for x in val))
        else:
            dtype = bool if field in _BOOL_TABLES else np.int32
            setattr(tabs, field, np.asarray(val, dtype=dtype))
    sc = doc.get("skip_consumers")
    consumers = (tuple(tuple(tuple(int(e) for e in slot) for slot in dev)
                       for dev in sc) if sc is not None else None)
    return SavedPlan(tables=tabs, skip_consumers=consumers,
                     overlap=bool(doc["overlap"]),
                     wire_dtype=str(doc["wire_dtype"]),
                     dp=int(doc.get("dp", 1)),
                     zero_stage=int(doc.get("zero_stage", 0)),
                     name=doc.get("name"))
