"""Static shape / tiling / memory checks for the Pallas TPU kernels.

Each kernel in ``repro.kernels`` asserts its grid divisibility at trace
time, deep inside a jit; this module lifts those launch constraints (plus
the TPU tiling and VMEM-residency facts the kernel docstrings promise)
into plain-arithmetic checks that run with **no jax import** — usable
from the analysis CLI, CI, and the ops-layer ``*_supported`` fallbacks
that route unsupported shapes to the reference implementations instead
of tripping a trace-time assert.

The checked properties mirror the kernels exactly:

- ``flash_attention_fwd`` — grid ``(BH, S/bq)`` with a fori_loop over
  ``T/bk`` K/V tiles; K/V BlockSpecs are *whole rows* ``(T, D)`` resident
  in VMEM, so long-T shapes are bounded by the ~16 MB/core budget here,
  not by the grid.
- ``skip_concat_matmul_fwd`` — grid ``(M/bm, N/bn)``, K-loop over
  ``D/bk``; all block dims clamp to the operand (``min(block, dim)``)
  and the clamped block must tile the dim exactly.
- ``gated_linear_scan_fwd`` — grid ``(R*C/bc, T/bt)`` with the time
  dimension iterated sequentially against a ``(1, bc)`` f32 VMEM scratch
  carry; ``block_t`` is a *static unroll* factor, so oversized values
  explode compile time (flagged as a warning).

Tiling constants are the TPU v4/v5 facts from the Pallas guide: 128-wide
lanes, dtype-dependent sublane minimums (f32 8, bf16 16, int8/fp8 32),
128x128 MXU, ~16 MB VMEM per core.

Findings come in two levels: ``error`` — the launch would assert or
cannot fit — and ``warn`` — it runs but off the hardware's fast path
(sub-tile blocks, VMEM pressure near the ceiling, huge unrolls).
``*_supported`` booleans are errors-only, matching the historical
``skip_concat_matmul_supported`` contract.
"""
from __future__ import annotations

import dataclasses

LANE = 128
MXU = 128
VMEM_BYTES = 16 * 2 ** 20
# minimum second-minor (sublane) tile per dtype; also the itemsize table
SUBLANE = {"float32": 8, "bfloat16": 16, "float16": 16,
           "int8": 32, "float8_e4m3fn": 32, "float8_e5m2": 32}
ITEMSIZE = {"float32": 4, "bfloat16": 2, "float16": 2,
            "int8": 1, "float8_e4m3fn": 1, "float8_e5m2": 1}
# static unroll lengths past this compile pathologically (linear_scan
# emits block_t dependent vector ops per tile)
MAX_UNROLL = 512


@dataclasses.dataclass(frozen=True)
class KernelFinding:
    level: str                   # "error" | "warn"
    detail: str

    def __str__(self) -> str:
        return f"{self.level}: {self.detail}"


@dataclasses.dataclass(frozen=True)
class KernelCheckReport:
    kernel: str
    params: dict
    findings: tuple[KernelFinding, ...]

    @property
    def ok(self) -> bool:
        """No errors — the launch is statically sound (warnings allowed)."""
        return all(f.level != "error" for f in self.findings)

    def errors(self) -> tuple[KernelFinding, ...]:
        return tuple(f for f in self.findings if f.level == "error")

    def __str__(self) -> str:
        head = (f"{self.kernel}(" + ", ".join(
            f"{k}={v}" for k, v in self.params.items()) + "): "
            + ("OK" if self.ok else "UNSUPPORTED"))
        return "\n".join([head] + [f"  {f}" for f in self.findings])


class _Checker:
    def __init__(self, kernel: str, params: dict, dtype: str):
        self.kernel, self.params = kernel, params
        self.findings: list[KernelFinding] = []
        self.dtype = dtype
        if dtype not in SUBLANE:
            self.error(f"dtype {dtype!r} has no TPU tiling rule; expected "
                       f"one of {tuple(SUBLANE)}")
            self.dtype = "float32"   # keep arithmetic going

    def error(self, detail: str):
        self.findings.append(KernelFinding("error", detail))

    def warn(self, detail: str):
        self.findings.append(KernelFinding("warn", detail))

    def positive(self, **dims: int) -> bool:
        bad = [k for k, v in dims.items() if v <= 0]
        for k in bad:
            self.error(f"{k}={dims[k]} is degenerate (the grid would be "
                       "empty or the BlockSpec zero-sized)")
        return not bad

    def tiles(self, name: str, dim: int, block: int) -> int:
        """Clamped block size + exact-tiling check (the kernel assert)."""
        b = min(block, dim)
        if dim % b != 0:
            self.error(f"{name}={dim} is not a multiple of its clamped "
                       f"block {b} (kernel asserts {name} % {b} == 0)")
        return b

    def aligned(self, name: str, val: int, *, lane: bool):
        unit = LANE if lane else SUBLANE[self.dtype]
        axis = "lane" if lane else f"{self.dtype} sublane"
        if val % unit != 0:
            self.warn(f"{name}={val} is not a multiple of the {unit}-wide "
                      f"{axis} tile — the tile pads and the "
                      "MXU/VPU runs below peak")

    def vmem(self, tiles_f32_bytes: int, **tiles_elems: int):
        itemsize = ITEMSIZE[self.dtype]
        total = sum(tiles_elems.values()) * itemsize + tiles_f32_bytes
        if total > VMEM_BYTES:
            names = ", ".join(tiles_elems)
            self.error(
                f"VMEM-resident blocks ({names} + f32 accumulators) need "
                f"{total / 2**20:.1f} MiB > ~{VMEM_BYTES // 2**20} MiB/core")
        elif total > VMEM_BYTES // 2:
            self.warn(
                f"VMEM-resident blocks use {total / 2**20:.1f} MiB — over "
                "half the core budget leaves no room for double-buffered "
                "pipelining")

    def report(self) -> KernelCheckReport:
        return KernelCheckReport(self.kernel, self.params,
                                 tuple(self.findings))


def check_flash_attention(BH: int, S: int, T: int, D: int, *,
                          dtype: str = "float32", block_q: int = 128,
                          block_k: int = 128,
                          window: int | None = None) -> KernelCheckReport:
    """Static launch check for ``flash_attention_fwd`` (flattened layout:
    BH = batch*heads, q (BH, S, D), k/v (BH, T, D))."""
    c = _Checker("flash_attention",
                 {"BH": BH, "S": S, "T": T, "D": D, "dtype": dtype,
                  "block_q": block_q, "block_k": block_k}, dtype)
    if not c.positive(BH=BH, S=S, T=T, D=D):
        return c.report()
    bq = c.tiles("S", S, block_q)
    bk = c.tiles("T", T, block_k)
    c.aligned("D", D, lane=True)
    c.aligned("block_q", bq, lane=False)
    c.aligned("block_k", bk, lane=False)
    if window is not None and window <= 0:
        c.error(f"window={window} masks every key (must be positive)")
    # q/o tiles are (bq, D); K and V are whole (T, D) rows in VMEM;
    # f32: q copy, acc (bq, D), per-tile k/v casts and the (bq, bk) scores
    f32 = 4 * (2 * bq * D + 2 * bk * D + 2 * bq * bk + 2 * bq)
    c.vmem(f32, q=bq * D, k=T * D, v=T * D, o=bq * D)
    return c.report()


def check_skip_concat_matmul(rows: int, d: int, n: int, *,
                             dtype: str = "float32", block_m: int = 128,
                             block_n: int = 128,
                             block_k: int = 128) -> KernelCheckReport:
    """Static launch check for ``skip_concat_matmul_fwd``
    (h/s (rows, d), w (2d, n))."""
    c = _Checker("skip_concat_matmul",
                 {"rows": rows, "d": d, "n": n, "dtype": dtype,
                  "block_m": block_m, "block_n": block_n,
                  "block_k": block_k}, dtype)
    if not c.positive(rows=rows, d=d, n=n):
        return c.report()
    bm = c.tiles("rows", rows, block_m)
    bn = c.tiles("n", n, block_n)
    bk = c.tiles("d", d, block_k)
    c.aligned("block_m", bm, lane=False)
    c.aligned("block_n", bn, lane=True)
    c.aligned("block_k", bk, lane=True)
    # h/s tiles (bm, d), w1/w2 tiles (d, bn), out (bm, bn); f32 acc +
    # per-K-tile casts
    f32 = 4 * (bm * bn + 2 * bm * bk + 2 * bk * bn)
    c.vmem(f32, h=bm * d, s=bm * d, w1=d * bn, w2=d * bn, o=bm * bn)
    return c.report()


def check_gated_linear_scan(R: int, T: int, C: int, *,
                            dtype: str = "float32", block_t: int = 128,
                            block_c: int = 128) -> KernelCheckReport:
    """Static launch check for ``gated_linear_scan_fwd`` (a/x (R, T, C))."""
    c = _Checker("gated_linear_scan",
                 {"R": R, "T": T, "C": C, "dtype": dtype,
                  "block_t": block_t, "block_c": block_c}, dtype)
    if not c.positive(R=R, T=T, C=C):
        return c.report()
    bt = c.tiles("T", T, block_t)
    bc = c.tiles("C", C, block_c)
    c.aligned("block_c", bc, lane=True)
    c.aligned("block_t", bt, lane=False)
    if bt > MAX_UNROLL:
        c.warn(f"block_t={bt} statically unrolls {bt} vector ops per "
               f"tile — past ~{MAX_UNROLL} this dominates compile time")
    # a/x/o tiles (bt, bc) + (1, bc) f32 scratch + f32 casts of a/x/rows
    f32 = 4 * (bc + 3 * bt * bc)
    c.vmem(f32, a=bt * bc, x=bt * bc, o=bt * bc)
    return c.report()


# ---- ops-layer fallback predicates (errors-only booleans) ----------------

def skip_concat_matmul_supported(rows: int, d: int, n: int,
                                 block: int = 128) -> bool:
    """Whether (rows, D) x (2D, N) operands tile the kernel's grid —
    the ops-layer fallback predicate (reference contraction otherwise)."""
    return check_skip_concat_matmul(rows, d, n, block_m=block,
                                    block_n=block, block_k=block).ok


def flash_attention_supported(S: int, T: int, D: int, *,
                              block_q: int = 128,
                              block_k: int = 128) -> bool:
    """Whether (S, T, D) attention shapes satisfy the kernel's grid
    asserts (per-head layout; BH does not affect supportability)."""
    return check_flash_attention(1, S, T, D, block_q=block_q,
                                 block_k=block_k).ok


def gated_linear_scan_supported(T: int, C: int, *, block_t: int = 128,
                                block_c: int = 128) -> bool:
    """Whether (T, C) scan shapes satisfy the kernel's grid asserts."""
    return check_gated_linear_scan(1, T, C, block_t=block_t,
                                   block_c=block_c).ok
