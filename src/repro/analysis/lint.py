"""AST policy linter: repo invariants ruff's rule set cannot express.

Three rules, each born from a real breakage mode in this codebase:

- **compat-only-experimental** — ``jax.experimental`` (and
  ``shard_map`` in particular) may be imported ONLY in
  ``runtime/compat.py``: jax moves experimental APIs between releases
  (``jax.experimental.shard_map`` -> ``jax.sharding``), and the compat
  shim is where the version probe lives.  The Pallas kernels are exempt —
  ``jax.experimental.pallas`` *is* their API surface and they are
  already isolated behind interpret-mode fallbacks.
- **core-lazy-jax** — no module-top ``jax`` import anywhere under
  ``core/``: the planning layer (partitioner, scheduler, cost models) is
  pure numpy/python by design, importable in schedulers, CI linters and
  notebook tooling without pulling in a multi-second jax import (or any
  accelerator runtime at all).  Function-local imports are fine — that
  is the sanctioned lazy pattern.  ``if TYPE_CHECKING:`` blocks are
  exempt.
- **guarded-placement-extrema** — in ``core/schedule.py``, ``max()`` /
  ``min()`` over a placements-derived iterable must either pass
  ``default=`` or sit in a scope that first guards the empty case
  (``if not ...: raise/return``): an empty-schedule edge case once
  turned into a bare ``ValueError: max() arg is an empty sequence``
  three layers from the actual bug.

CLI: ``python -m repro.analysis.lint [paths...]`` (default: ``src``,
``tests``, ``benchmarks`` under the repo root).  Exit 0 when clean.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import sys

RULES = ("compat-only-experimental", "core-lazy-jax",
         "guarded-placement-extrema")

#: modules allowed to touch jax.experimental / shard_map directly —
#: the compat shim itself, plus runtime/sharding.py (the PartitionSpec
#: rule tables sit next to the sharding entry points it re-exports)
COMPAT_MODULES = ("runtime/compat.py", "runtime/sharding.py")
COMPAT_MODULE = COMPAT_MODULES[0]   # back-compat alias
#: subtrees exempt from the compat rule (pallas IS the kernel API)
KERNEL_PREFIX = "kernels/"


@dataclasses.dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str
    line: int
    detail: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.detail}"


def _repro_relpath(path: pathlib.Path) -> str | None:
    """Path relative to the ``repro`` package root, or None outside it."""
    parts = path.as_posix().split("/")
    if "repro" in parts:
        return "/".join(parts[parts.index("repro") + 1:])
    return None


def _imported_modules(node: ast.AST):
    if isinstance(node, ast.Import):
        for alias in node.names:
            yield alias.name
    elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
        yield node.module


class _FileLinter(ast.NodeVisitor):
    def __init__(self, path: pathlib.Path, rel: str | None):
        self.path, self.rel = path, rel
        self.findings: list[LintFinding] = []
        self._func_depth = 0
        self._type_checking = 0

    def flag(self, rule: str, node: ast.AST, detail: str):
        self.findings.append(
            LintFinding(rule, str(self.path), node.lineno, detail))

    # ---- scope tracking ------------------------------------------------
    def visit_FunctionDef(self, node):
        self._func_depth += 1
        self.generic_visit(node)
        self._func_depth -= 1

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef

    def visit_If(self, node):
        is_tc = isinstance(node.test, ast.Name) and \
            node.test.id == "TYPE_CHECKING"
        self._type_checking += is_tc
        self.generic_visit(node)
        self._type_checking -= is_tc

    # ---- rule 1 + 2: import policy -------------------------------------
    def _check_import(self, node):
        in_core = self.rel is not None and self.rel.startswith("core/")
        exempt_compat = self.rel is None or self.rel in COMPAT_MODULES \
            or (self.rel or "").startswith(KERNEL_PREFIX)
        for mod in _imported_modules(node):
            root = mod.split(".")[0]
            if not exempt_compat and (
                    mod.startswith("jax.experimental")
                    or (isinstance(node, ast.ImportFrom)
                        and mod == "jax"
                        and any(a.name == "experimental"
                                for a in node.names))):
                self.flag(
                    "compat-only-experimental", node,
                    f"import of {mod!r}: jax.experimental/shard_map may "
                    "only be imported via repro.runtime.compat (kernels "
                    "exempt)")
            if in_core and root == "jax" and self._func_depth == 0 \
                    and not self._type_checking:
                self.flag(
                    "core-lazy-jax", node,
                    "module-top jax import under core/ — the planning "
                    "layer must import without jax; move it inside the "
                    "function that needs it")
        self.generic_visit(node)

    visit_Import = _check_import
    visit_ImportFrom = _check_import


def _mentions_placements(node: ast.AST) -> bool:
    return any((isinstance(n, ast.Name) and "placement" in n.id)
               or (isinstance(n, ast.Attribute) and "placement" in n.attr)
               for n in ast.walk(node))


def _scope_nodes(scope: ast.AST):
    """Walk a scope's own statements, not those of nested functions
    (each nested def is analyzed as its own scope)."""
    stack = list(ast.iter_child_nodes(scope))
    while stack:
        n = stack.pop()
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
            continue
        yield n
        stack.extend(ast.iter_child_nodes(n))


def _has_empty_guard(scope: ast.AST) -> bool:
    """An ``if`` mentioning placements whose body raises or returns —
    the sanctioned empty-schedule guard pattern."""
    for n in _scope_nodes(scope):
        if isinstance(n, ast.If) and _mentions_placements(n.test) and any(
                isinstance(s, (ast.Raise, ast.Return))
                for b in n.body for s in ast.walk(b)):
            return True
    return False


def _check_extrema(tree: ast.AST, path: pathlib.Path
                   ) -> list[LintFinding]:
    findings = []
    scopes = [tree] + [n for n in ast.walk(tree)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]
    for scope in scopes:
        guarded = _has_empty_guard(scope)
        for n in _scope_nodes(scope):
            if not (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                    and n.func.id in ("max", "min")):
                continue
            if len(n.args) != 1 or any(k.arg == "default"
                                       for k in n.keywords):
                continue        # max(a, b) / max(..., default=...) are fine
            if not _mentions_placements(n.args[0]):
                continue
            if guarded:
                continue
            findings.append(LintFinding(
                "guarded-placement-extrema", str(path), n.lineno,
                f"bare {n.func.id}() over a placements-derived iterable "
                "with no default= and no empty-schedule guard in scope "
                "(empty schedules raise a bare ValueError here)"))
    return findings


def lint_file(path: pathlib.Path) -> list[LintFinding]:
    rel = _repro_relpath(path)
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [LintFinding("parse", str(path), e.lineno or 0, str(e))]
    linter = _FileLinter(path, rel)
    linter.visit(tree)
    findings = linter.findings
    if rel == "core/schedule.py":
        findings += _check_extrema(tree, path)
    return findings


def lint_paths(paths) -> list[LintFinding]:
    findings: list[LintFinding] = []
    for p in paths:
        p = pathlib.Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_file(f))
    return findings


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        root = pathlib.Path(__file__).resolve().parents[3]
        argv = [str(root / d) for d in ("src", "tests", "benchmarks")
                if (root / d).is_dir()]
    findings = lint_paths(argv)
    for f in findings:
        print(f)
    print(f"policy lint: {len(findings)} finding(s) in "
          f"{len(argv)} path(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
