"""Static plan verification: prove a lowered plan safe before it runs.

The compile path validates *schedules* (``core.schedule.validate_schedule``,
constraint families 6-11) and the lowering rejects shapes the executors
cannot realize (``runtime.schedule_exec.PlanError``) — but until now
nothing certified the *lowered step tables themselves*: the rotating-buffer
slot assignments, channel-activity masks, and double-buffered hop ordering
the scan bodies actually execute.  This package closes that gap with pure
host-side analyses (no jax import, no execution):

- :mod:`repro.analysis.dataflow` — abstractly interprets a lowered
  :class:`~repro.runtime.schedule_exec.StepTables` device program over the
  rotating ``W_down``/``W_up``/``W_turn``/``W_skip`` buffers and proves it
  race-free (no store clobbers a live slot), initialization-sound (every
  read sees exactly one matching store), deadlock-free (ring sends and
  receives pair one hop apart every step, in both the synchronous and the
  overlapped double-buffered lowering) and wire-dtype consistent.
- :mod:`repro.analysis.certificate` — bundles the proof into a
  machine-readable :class:`PlanCertificate` (JSON), attached to
  ``CompiledPipeline.certify()`` and verifiable offline.
- :mod:`repro.analysis.verify` — ``python -m repro.analysis.verify`` CLI:
  certify tier-1 config plans or a saved plan file.
- :mod:`repro.analysis.kernel_check` — import-free static shape / tiling /
  dtype checks for the Pallas kernels.
- :mod:`repro.analysis.lint` — AST policy linter for repo invariants ruff
  cannot express (compat-only ``jax.experimental`` imports, lazy jax under
  ``core/``, guarded ``max()``/``min()`` over placement sequences).
"""
from repro.analysis.dataflow import (CHECKS, DataflowReport, Violation,
                                     interpret_tables)
from repro.analysis.certificate import (PlanCertificate, certify_plan,
                                        certify_schedule, certify_tables,
                                        export_plan, load_plan)

__all__ = [
    "CHECKS",
    "DataflowReport",
    "Violation",
    "interpret_tables",
    "PlanCertificate",
    "certify_plan",
    "certify_schedule",
    "certify_tables",
    "export_plan",
    "load_plan",
]
