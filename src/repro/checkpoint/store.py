"""Plan-aware sharded checkpointing with verified manifests.

Layout (schema ``repro.checkpoint/v2``):

    <dir>/step_000000123/
        shard_00000.npz     # host 0's leaves (flat leaf index -> array)
        shard_00000.json    # sidecar: SHA-256 of the .npz + its leaf list
        shard_00001.npz     # host 1's leaves ...
        shard_00001.json
        manifest.json       # tree metadata, EXPECTED shard list, the
                            # saving plan's state-spec (+ fingerprint)

Every host writes only its leaves (leaf-wise round-robin) plus a sidecar
recording the shard's SHA-256 — hosts never need each other's hashes.
Host 0 writes ``manifest.json`` naming every *expected* shard, so the
manifest alone is **not** the completeness marker: a step is complete
only when the manifest exists AND every listed shard is present, its
sidecar hash verifies, and the shards jointly cover every leaf
(:func:`verify_step`).  This closes the multi-host race where host 0's
manifest landed before the other hosts' shards.

All writes are atomic (dot-prefixed tmp + ``os.replace``); readers never
see a torn file, and GC sweeps stale tmps.

Fault-tolerance contract used by ``launch/train.py``:

- save every N steps (async via a background thread; transient IO errors
  retry with exponential backoff and a final failure degrades to
  keep-training-and-warn — the step loop never crashes on a bad disk),
- on restart, :func:`restore_checkpoint` returns the newest *verified*
  step; ``strict=False`` falls back past corrupt/partial steps,
- the data pipeline is stateless given (step, host_id), so resume is
  exact; when the plan changed, ``runtime.resilience`` de-stacks the
  saved state through the manifest's recorded plan spec,
- with ``num_hosts > 1`` each worker process saves its own shard and then
  rendezvouses on :func:`wait_step_complete` — the shard files themselves
  are the barrier markers, so no host advances past a step commit until
  every host's shard verifies (and host 0's GC can never observe a step
  the cluster still considers in flight as the newest complete one).

jax is imported lazily, inside the (de)serialization paths that need a
pytree — verification, completeness scans, GC and ``wait_step_complete``
are pure hashing/JSON, so the training supervisor can read checkpoint
state without touching an accelerator runtime.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
import time
import warnings
from typing import Any

import numpy as np

Pytree = Any

MANIFEST_SCHEMA = "repro.checkpoint/v2"


class CheckpointError(ValueError):
    """Structured checkpoint failure (mirrors ``schedule_exec.PlanError``).

    ``step``/``shard``/``reason`` survive as fields so drivers can log or
    branch on them; the message carries the same context for humans.
    Subclasses ``ValueError`` so legacy ``except ValueError`` callers
    keep working.
    """

    def __init__(self, message: str, *, step: int | None = None,
                 shard: str | None = None, reason: str | None = None):
        self.step = step
        self.shard = shard
        self.reason = reason
        ctx = ", ".join(f"{k}={v}" for k, v in
                        (("step", step), ("shard", shard),
                         ("reason", reason)) if v is not None)
        super().__init__(f"[checkpoint{'; ' + ctx if ctx else ''}] "
                         f"{message}")


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:09d}")


def _shard_name(host_id: int) -> str:
    return f"shard_{host_id:05d}"


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _atomic_write(path: str, write_fn) -> None:
    tmp = os.path.join(os.path.dirname(path),
                       f".{os.path.basename(path)}.tmp{os.getpid()}")
    write_fn(tmp)
    os.replace(tmp, path)


def _atomic_write_json(path: str, doc: dict) -> None:
    def w(tmp):
        with open(tmp, "w") as f:
            json.dump(doc, f)
    _atomic_write(path, w)


def save_checkpoint(directory: str, step: int, tree: Pytree, *,
                    host_id: int = 0, num_hosts: int = 1,
                    extra: dict | None = None, plan: dict | None = None,
                    io_fault=None) -> str:
    """Blocking save of this host's shard.  Returns the checkpoint path.

    ``plan``: the saving plan's state-spec
    (``CompiledPipeline.state_spec()``) recorded in the manifest — what
    elastic restore de-stacks through.  ``io_fault``: optional hook
    called before any byte is written; raising ``OSError`` simulates a
    transient storage failure (the whole save is retryable).
    """
    import jax

    path = _step_dir(directory, step)
    if io_fault is not None:
        io_fault(step)
    os.makedirs(path, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten(tree)
    mine = [i for i in range(len(flat)) if i % num_hosts == host_id]
    arrays = {f"a{i}": np.asarray(jax.device_get(flat[i])) for i in mine}
    shard = _shard_name(host_id)
    npz = os.path.join(path, shard + ".npz")

    def write_npz(tmp):
        # write through a file object: np.savez(str_path) appends ".npz"
        # to extension-less names, which would break the atomic rename
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)

    _atomic_write(npz, write_npz)
    _atomic_write_json(os.path.join(path, shard + ".json"),
                       {"file": shard + ".npz", "sha256": _sha256(npz),
                        "leaves": mine})
    if host_id == 0:
        manifest = {
            "schema": MANIFEST_SCHEMA,
            "step": step,
            "num_hosts": num_hosts,
            "num_leaves": len(flat),
            "leaves": [{"shape": list(np.shape(x)),
                        "dtype": str(np.asarray(x).dtype)} for x in flat],
            "shards": [_shard_name(h) + ".npz" for h in range(num_hosts)],
            "plan": plan,
            "extra": extra or {},
        }
        _atomic_write_json(os.path.join(path, "manifest.json"), manifest)
    return path


# ---------------------------------------------------------------------------
# Verification
# ---------------------------------------------------------------------------

def read_manifest(directory: str, step: int) -> dict:
    path = os.path.join(_step_dir(directory, step), "manifest.json")
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        raise CheckpointError("manifest.json missing (incomplete save)",
                              step=step, reason="no-manifest") from None
    except json.JSONDecodeError as e:
        raise CheckpointError(f"manifest.json unreadable: {e}",
                              step=step, reason="manifest-corrupt") from None


def verify_step(directory: str, step: int) -> dict:
    """Full completeness + integrity check of one step; returns its
    manifest.  A step passes only when the manifest exists, every listed
    shard is present with a sidecar whose SHA-256 matches the bytes on
    disk, and the shards jointly cover every leaf."""
    man = read_manifest(directory, step)
    if man.get("schema") != MANIFEST_SCHEMA:
        raise CheckpointError(
            f"unknown manifest schema {man.get('schema')!r} "
            f"(expected {MANIFEST_SCHEMA})", step=step, reason="schema")
    path = _step_dir(directory, step)
    covered: set[int] = set()
    for shard in man["shards"]:
        npz = os.path.join(path, shard)
        if not os.path.exists(npz):
            raise CheckpointError("listed shard missing (incomplete "
                                  "multi-host save)", step=step,
                                  shard=shard, reason="missing-shard")
        side_path = os.path.join(path, shard[:-len(".npz")] + ".json")
        try:
            with open(side_path) as f:
                side = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            raise CheckpointError("shard sidecar missing/unreadable",
                                  step=step, shard=shard,
                                  reason="no-sidecar") from None
        digest = _sha256(npz)
        if digest != side["sha256"]:
            raise CheckpointError(
                f"shard bytes do not match recorded SHA-256 "
                f"({digest[:12]} != {side['sha256'][:12]})",
                step=step, shard=shard, reason="checksum-mismatch")
        covered.update(side["leaves"])
    if covered != set(range(man["num_leaves"])):
        missing = sorted(set(range(man["num_leaves"])) - covered)
        raise CheckpointError(
            f"shards cover {len(covered)}/{man['num_leaves']} leaves "
            f"(missing {missing[:8]}...)", step=step,
            reason="incomplete-leaves")
    return man


def _all_step_dirs(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    return sorted(int(m.group(1)) for m in
                  (re.fullmatch(r"step_(\d+)", n)
                   for n in os.listdir(directory)) if m)


def complete_steps(directory: str) -> list[int]:
    """Ascending list of steps that pass full verification."""
    out = []
    for s in _all_step_dirs(directory):
        try:
            verify_step(directory, s)
        except CheckpointError:
            continue
        out.append(s)
    return out


def latest_step(directory: str) -> int | None:
    """Newest step that passes full verification (hash-checked), or None."""
    steps = complete_steps(directory)
    return steps[-1] if steps else None


def wait_step_complete(directory: str, step: int, *,
                       timeout: float = 120.0, poll: float = 0.05) -> dict:
    """Block until ``step`` passes full verification; the multi-host
    barrier on step commit.

    Each worker calls this right after writing its own shard: the shard
    files (+ sidecars + manifest) double as the barrier markers, so no
    host advances past a checkpoint step until every host's bytes are on
    disk and hash-verified — the completeness protocol is exercised by
    the actual separate writer processes, not simulated.  Returns the
    verified manifest; raises :class:`CheckpointError` with
    ``reason="commit-timeout"`` (carrying the last verification failure)
    when some host never lands its shard — a dead host turns the barrier
    into a detected failure instead of a silent wedge.
    """
    deadline = time.time() + timeout
    while True:
        try:
            return verify_step(directory, step)
        except CheckpointError as e:
            if time.time() > deadline:
                raise CheckpointError(
                    f"step did not become complete within {timeout:.1f}s "
                    f"(last failure: {e}) — a peer host likely died "
                    "mid-commit", step=step,
                    reason="commit-timeout") from e
            time.sleep(poll)


# ---------------------------------------------------------------------------
# Restore
# ---------------------------------------------------------------------------

def _load_step(directory: str, step: int, man: dict, like: Pytree,
               shardings: Pytree | None, expect_shapes: bool) -> Pytree:
    import jax

    path = _step_dir(directory, step)
    flat, treedef = jax.tree_util.tree_flatten(like)
    if len(flat) != man["num_leaves"]:
        raise CheckpointError(
            f"checkpoint has {man['num_leaves']} leaves, model expects "
            f"{len(flat)} — architecture mismatch", step=step,
            reason="structure")
    data: dict[int, np.ndarray] = {}
    for shard in man["shards"]:
        with np.load(os.path.join(path, shard)) as z:
            for k in z.files:
                data[int(k[1:])] = z[k]
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    out = []
    for i, (proto, shd) in enumerate(zip(flat, shard_flat)):
        if i not in data:
            raise CheckpointError(f"leaf {i} missing from shards",
                                  step=step, reason="missing-leaf")
        arr = data[i]
        if expect_shapes and list(arr.shape) != list(np.shape(proto)):
            raise CheckpointError(
                f"leaf {i} shape {list(arr.shape)} != "
                f"{list(np.shape(proto))} (pass expect_shapes=False for "
                "the elastic path)", step=step, reason="shape")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_checkpoint(directory: str, like: Pytree, *,
                       step: int | None = None,
                       shardings: Pytree | None = None,
                       strict: bool = True,
                       expect_shapes: bool = True) -> tuple[Pytree, int]:
    """Restore the newest verified (or given) step into ``like``'s
    structure.

    Every candidate step is hash-verified before a byte is deserialized.
    ``strict=True`` raises :class:`CheckpointError` on the first
    corrupt/partial candidate; ``strict=False`` walks backwards to the
    newest step that fully verifies (logging what it skipped) and only
    raises when no step survives.

    ``shardings``: optional pytree of NamedShardings for the *current*
    mesh; arrays are placed with ``jax.device_put`` accordingly.
    ``expect_shapes=False`` skips leaf-shape checks — the elastic path,
    where the caller re-stacks through ``runtime.resilience``.
    """
    candidates = ([step] if step is not None
                  else sorted(_all_step_dirs(directory), reverse=True))
    if not candidates:
        raise CheckpointError(f"no checkpoints under {directory}",
                              reason="empty")
    skipped: list[int] = []
    last_err: CheckpointError | None = None
    for s in candidates:
        try:
            man = verify_step(directory, s)
            tree = _load_step(directory, s, man, like, shardings,
                              expect_shapes)
        except CheckpointError as e:
            if strict:
                raise
            skipped.append(s)
            last_err = e
            continue
        if skipped:
            print(f"[checkpoint] step(s) {skipped} failed verification "
                  f"(last: {last_err}); fell back to step {s}")
        return tree, s
    assert last_err is not None
    raise last_err


# ---------------------------------------------------------------------------
# Manager
# ---------------------------------------------------------------------------

class CheckpointManager:
    """Async, bounded-retention manager with retry/backoff saves.

    ``plan``: state-spec dict stamped into every manifest.  ``io_fault``:
    fault-injection hook forwarded to :func:`save_checkpoint`.  Saves
    retry transient ``OSError`` up to ``retries`` times with exponential
    backoff (``backoff * 2**attempt`` seconds); a final failure warns
    and returns ``None`` — checkpointing degrades, training never
    crashes on storage trouble.
    """

    def __init__(self, directory: str, *, keep: int = 3, host_id: int = 0,
                 num_hosts: int = 1, retries: int = 3,
                 backoff: float = 0.05, plan: dict | None = None,
                 io_fault=None):
        self.directory = directory
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.retries = retries
        self.backoff = backoff
        self.plan = plan
        self.io_fault = io_fault
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree: Pytree,
             extra: dict | None = None) -> str | None:
        """Blocking save with retry/backoff; returns the path or None."""
        last: OSError | None = None
        for attempt in range(self.retries + 1):
            try:
                path = save_checkpoint(
                    self.directory, step, tree, host_id=self.host_id,
                    num_hosts=self.num_hosts, extra=extra, plan=self.plan,
                    io_fault=self.io_fault)
                self._gc()
                return path
            except OSError as e:
                last = e
                if attempt < self.retries:
                    delay = self.backoff * (2 ** attempt)
                    print(f"[checkpoint] save at step {step} failed "
                          f"({e}); retry {attempt + 1}/{self.retries} "
                          f"in {delay:.2f}s")
                    time.sleep(delay)
        warnings.warn(
            f"checkpoint save at step {step} failed after "
            f"{self.retries + 1} attempts ({last}); training continues "
            "WITHOUT this checkpoint", RuntimeWarning, stacklevel=2)
        return None

    def save_async(self, step: int, tree: Pytree,
                   extra: dict | None = None) -> None:
        import jax

        self.wait()                           # one in flight at a time
        tree = jax.device_get(tree)           # snapshot before async write
        self._thread = threading.Thread(
            target=lambda: self.save(step, tree, extra), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        """Retention keyed on VERIFIED-complete steps only.

        Incomplete step dirs never count toward ``keep`` (so garbage can
        no longer crowd out every good checkpoint); incomplete dirs
        *older* than the newest complete step are swept (newer ones may
        still be mid-write on another host), as are stale tmp files/dirs
        from crashed saves.  Host 0 owns GC.
        """
        if self.host_id != 0:
            return
        complete = complete_steps(self.directory)
        for s in (complete[:-self.keep] if self.keep else []):
            shutil.rmtree(_step_dir(self.directory, s), ignore_errors=True)
        newest = complete[-1] if complete else None
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name)
            if name.startswith(".") or ".tmp" in name:
                (shutil.rmtree(full, ignore_errors=True)
                 if os.path.isdir(full) else _unlink_quiet(full))
                continue
            m = re.fullmatch(r"step_(\d+)", name)
            if m and newest is not None and int(m.group(1)) < newest \
                    and int(m.group(1)) not in complete:
                shutil.rmtree(full, ignore_errors=True)


def _unlink_quiet(path: str) -> None:
    try:
        os.remove(path)
    except OSError:
        pass
