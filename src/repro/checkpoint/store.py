"""Sharded checkpointing with manifest, async save, and elastic restore.

Layout:
    <dir>/step_000123/
        manifest.json       # tree structure, leaf shapes/dtypes, mesh info
        shard_00000.npz     # this host's leaves (flat index -> array)

Every host writes only its addressable shards; restore re-assembles and
re-shards onto the *current* mesh (which may differ from the saving mesh —
elastic scaling / failed-node replacement).  On a single-process CPU run
there is one shard file; the manifest format is nevertheless multi-host.

Fault-tolerance contract used by ``launch/train.py``:
- save every N steps (async via a background thread; the main loop never
  blocks on serialization),
- on SIGTERM/restart, ``restore_checkpoint(dir)`` returns the latest
  *complete* step (a checkpoint is complete when ``manifest.json`` exists —
  it is written last),
- the data pipeline is stateless given (step, host_id), so resume is exact.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

Pytree = Any


def _flatten_with_paths(tree: Pytree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree: Pytree, *,
                    host_id: int = 0, num_hosts: int = 1,
                    extra: dict | None = None) -> str:
    """Blocking save.  Returns the checkpoint path."""
    path = os.path.join(directory, f"step_{step:09d}")
    tmp = path + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)
    flat, treedef = _flatten_with_paths(tree)
    arrays = {}
    for i, leaf in enumerate(flat):
        if i % num_hosts == host_id:          # leaf-wise host sharding
            arrays[f"a{i}"] = np.asarray(jax.device_get(leaf))
    np.savez(os.path.join(tmp, f"shard_{host_id:05d}.npz"), **arrays)
    os.makedirs(path, exist_ok=True)
    for f in os.listdir(tmp):
        os.replace(os.path.join(tmp, f), os.path.join(path, f))
    shutil.rmtree(tmp, ignore_errors=True)
    if host_id == 0:
        manifest = {
            "step": step,
            "num_hosts": num_hosts,
            "num_leaves": len(flat),
            "leaves": [{"shape": list(np.shape(x)),
                        "dtype": str(np.asarray(x).dtype)} for x in flat],
            "extra": extra or {},
        }
        mtmp = os.path.join(path, "manifest.json.tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, os.path.join(path, "manifest.json"))
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            s = int(m.group(1))
            best = s if best is None or s > best else best
    return best


def restore_checkpoint(directory: str, like: Pytree, *, step: int | None = None,
                       shardings: Pytree | None = None) -> tuple[Pytree, int]:
    """Restore the latest (or given) step into the structure of ``like``.

    ``shardings``: optional pytree of NamedShardings for the *current* mesh;
    arrays are placed with jax.device_put accordingly (elastic re-shard)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data: dict[int, np.ndarray] = {}
    for name in os.listdir(path):
        if name.startswith("shard_") and name.endswith(".npz"):
            with np.load(os.path.join(path, name)) as z:
                for k in z.files:
                    data[int(k[1:])] = z[k]
    flat, treedef = _flatten_with_paths(like)
    if len(flat) != manifest["num_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, "
            f"model expects {len(flat)} — architecture mismatch")
    out = []
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    for i, (proto, shd) in enumerate(zip(flat, shard_flat)):
        if i not in data:
            raise ValueError(f"leaf {i} missing from checkpoint shards")
        arr = data[i]
        if list(arr.shape) != list(np.shape(proto)):
            raise ValueError(f"leaf {i} shape {arr.shape} != {np.shape(proto)}")
        out.append(jax.device_put(arr, shd) if shd is not None
                   else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class CheckpointManager:
    """Async, bounded-retention checkpoint manager."""

    def __init__(self, directory: str, *, keep: int = 3, host_id: int = 0,
                 num_hosts: int = 1):
        self.directory = directory
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree: Pytree, extra: dict | None = None):
        self.wait()                           # one in flight at a time
        tree = jax.device_get(tree)           # snapshot before async write

        def work():
            save_checkpoint(self.directory, step, tree,
                            host_id=self.host_id, num_hosts=self.num_hosts,
                            extra=extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for m in
            (re.fullmatch(r"step_(\d+)", n)
             for n in os.listdir(self.directory))
            if m)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
