from repro.checkpoint.store import (CheckpointError, CheckpointManager,
                                    complete_steps, latest_step,
                                    read_manifest, restore_checkpoint,
                                    save_checkpoint, verify_step,
                                    wait_step_complete)

__all__ = ["CheckpointError", "CheckpointManager", "complete_steps",
           "latest_step", "read_manifest", "restore_checkpoint",
           "save_checkpoint", "verify_step", "wait_step_complete"]
