from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, cosine_schedule,
                               int8_adamw_init, int8_adamw_update)
