"""Optimizers (optax is not available offline; implemented from scratch).

- AdamW with decoupled weight decay, global-norm clipping, cosine schedule.
- int8-quantized AdamW moments (block-wise absmax quantization): a
  distributed-optimization memory trick — cuts optimizer state from 8 to
  ~2 bytes/param, the difference between DeepSeek-V3-scale training fitting
  on 512 v5e chips or not (EXPERIMENTS.md §Dry-run memory notes).

All state pytrees mirror the param tree, so any sharding specs built for
params apply leaf-wise to the state (ZeRO-1 = shard these specs over 'data').
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    warm = base_lr * (step + 1) / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 *
                     (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def clip_by_global_norm(grads: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


# --------------------------------------------------------------------------
# fp32-state AdamW
# --------------------------------------------------------------------------

def adamw_init(params: Pytree) -> Pytree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params: Pytree, grads: Pytree, state: Pytree,
                 cfg: AdamWConfig, lr=None) -> tuple[Pytree, Pytree]:
    step = state["step"] + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    if cfg.clip_norm:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)

    # separate maps so arbitrary param pytrees (incl. tuples of stacks from
    # the pipeline runtime) survive structurally.
    new_m = jax.tree.map(
        lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g.astype(jnp.float32),
        state["m"], grads)
    new_v = jax.tree.map(
        lambda v, g: cfg.b2 * v + (1 - cfg.b2)
        * jnp.square(g.astype(jnp.float32)),
        state["v"], grads)
    new_params = jax.tree.map(
        lambda p, m, v: (p.astype(jnp.float32)
                         - lr * ((m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
                                 + cfg.weight_decay * p.astype(jnp.float32))
                         ).astype(p.dtype),
        params, new_m, new_v)
    return new_params, {"m": new_m, "v": new_v, "step": step}


# --------------------------------------------------------------------------
# int8-state AdamW (block-wise absmax quantization of m and v)
# --------------------------------------------------------------------------

_BLOCK = 256
# pad the block count to a multiple of 32 so the quantized state tensors
# stay evenly shardable over up to 32-way ZeRO axes (pod x data).
_BLOCK_ALIGN = 32


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % (_BLOCK * _BLOCK_ALIGN)
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def int8_adamw_init(params: Pytree) -> Pytree:
    def zq(p):
        q, s = _quantize(jnp.zeros(p.shape, jnp.float32))
        return {"q": q, "s": s}
    return {
        "m": jax.tree.map(zq, params),
        "v": jax.tree.map(zq, params),
        "step": jnp.zeros((), jnp.int32),
    }


def int8_adamw_update(params: Pytree, grads: Pytree, state: Pytree,
                      cfg: AdamWConfig, lr=None) -> tuple[Pytree, Pytree]:
    step = state["step"] + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    if cfg.clip_norm:
        grads, _ = clip_by_global_norm(grads, cfg.clip_norm)

    def upd(p, g, mq, vq):
        g32 = g.astype(jnp.float32)
        m = _dequantize(mq["q"], mq["s"], p.shape)
        v = _dequantize(vq["q"], vq["s"], p.shape)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        u = (m / b1c) / (jnp.sqrt(jnp.maximum(v, 0.0) / b2c) + cfg.eps)
        new_p = (p.astype(jnp.float32)
                 - lr * (u + cfg.weight_decay * p.astype(jnp.float32)))
        nmq, nms = _quantize(m)
        nvq, nvs = _quantize(v)
        return (new_p.astype(p.dtype), {"q": nmq, "s": nms},
                {"q": nvq, "s": nvs})

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in
            zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in outs])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in outs])
    return new_params, {"m": new_m, "v": new_v, "step": step}
