"""xlstm-125m [ssm]: 12L d=768 4H vocab=50304 — sLSTM + mLSTM blocks
(xLSTM[7:1]-style: sLSTM at every 6th layer).

Recurrent state is O(1) in sequence length, so all decode shapes including
long_500k run; the 'cache' is the per-layer recurrent state.
"""
import jax
import jax.numpy as jnp
from repro.configs.base import ArchBundle, ShapeSpec, token_batch_struct
from repro.models import xlstm as xm
from repro.models.xlstm import XLSTMConfig
from repro.train.steps import ParallelPlan

CFG = XLSTMConfig(
    name="xlstm-125m", vocab=50304, d_model=768, n_layers=12, n_heads=4,
    slstm_every=6, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)

PLANS = {
    "train_4k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                             batch_axes=("pod", "data")),
    "prefill_32k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                                batch_axes=("pod", "data")),
    "decode_32k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                               batch_axes=("pod", "data")),
    "long_500k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                              batch_axes=("data",),
                              notes="state is O(1); context length free"),
}


def batch_struct(shape: ShapeSpec, plan=None):
    # recurrent training cost is O(S); cap the traced train seq at 4k.
    return token_batch_struct(shape, CFG.vocab)


def loss_fn(params, batch, rng):
    return xm.xlstm_loss(params, batch, CFG)


def cache_struct(shape: ShapeSpec):
    return jax.eval_shape(lambda: xm.init_states(CFG, shape.global_batch))


def make_decode_fn(shape: ShapeSpec):
    def decode(params, token, states):
        return xm.decode_step(params, token, states, CFG)
    return decode


def get_bundle():
    return ArchBundle(
        name="xlstm-125m", family="ssm", cfg=CFG,
        init_fn=lambda key: xm.init_xlstm(key, CFG),
        loss_fn=loss_fn, batch_struct=batch_struct, plans=PLANS,
        shape_support={s: "ok" for s in
                       ("train_4k", "prefill_32k", "decode_32k", "long_500k")},
        param_count=CFG.param_count(), active_param_count=CFG.param_count(),
        make_decode_fn=make_decode_fn, cache_struct=cache_struct,
        notes="recurrent state O(1); long_500k trivially supported")
