"""deepseek-v3-671b [moe]: 61L d=7168 128H MLA, MoE d_ff=2048 256 experts
top-8 + 1 shared, first 3 layers dense (d_ff=18432), vocab=129280, MTP.

The heavyweight: MLA compressed KV cache (576 B/token vs 64 KiB for MHA),
EP+TP over 'model', FSDP over 'data' (x 'pod'), int8-quantized Adam moments
(fp32 moments alone would need 31 GB/chip at 256 chips — §Dry-run notes),
per-layer remat.  61 layers are prime, so PP is structurally unavailable;
PULSE's collocation insight appears as the tied placement of embedding +
MTP head handled inside one GSPMD partition.
"""
import jax.numpy as jnp
from repro.configs.lm_common import lm_bundle
from repro.models.lm import LMConfig
from repro.models.layers import MLAConfig, MoEConfig
from repro.train.steps import ParallelPlan

CFG = LMConfig(
    name="deepseek-v3-671b", vocab=129280, d_model=7168, n_layers=61,
    mla=MLAConfig(d_model=7168, n_heads=128, q_lora_rank=1536,
                  kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    d_ff=18432, n_dense_layers=3,
    moe=MoEConfig(d_model=7168, d_ff=2048, n_experts=256, top_k=8,
                  n_shared=1, shared_d_ff=2048, capacity_factor=1.25),
    moe_dispatch="scatter", mtp=True,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True)

PLANS = {
    "train_4k": ParallelPlan(tp_axis="model", ep=True,
                             fsdp_axes=("pod", "data"),
                             int8_optimizer=True,
                             notes="EP/TP-16 x FSDP, int8 Adam moments"),
    "prefill_32k": ParallelPlan(tp_axis="model", ep=True,
                                fsdp_axes=("pod", "data")),
    "decode_32k": ParallelPlan(tp_axis="model", ep=True,
                               fsdp_axes=("pod", "data"),
                               seq_shard_axis="model",
                               notes="MLA latent cache seq-sharded over TP"),
    "long_500k": ParallelPlan(),
}


def get_bundle():
    return lm_bundle("deepseek-v3-671b", CFG, PLANS,
                     notes="MLA + 256-expert MoE + MTP")
