"""zamba2-2.7b [hybrid]: 54 Mamba2 blocks d=2560 (ssm_state=64) + 2 shared
full-attention blocks (32H, d_ff=10240) applied every 6 layers.

The genuinely PULSE-relevant assigned arch: the shared block's parameter
reuse sites are long-range graph edges; the partitioner's collocation
analysis applies (benchmarks/partition_balance.py exports its BlockGraph).
long_500k runs: Mamba state is O(1); the 9 shared-attention KV caches are
sequence-sharded over 'data' at batch=1.
"""
import jax
import jax.numpy as jnp
from repro.configs.base import ArchBundle, ShapeSpec, token_batch_struct
from repro.models import mamba as zm
from repro.models.mamba import Zamba2Config, Mamba2Config
from repro.models.layers import AttnConfig
from repro.train.steps import ParallelPlan

CFG = Zamba2Config(
    name="zamba2-2.7b", vocab=32000, d_model=2560, n_layers=54,
    mamba=Mamba2Config(d_model=2560, d_state=64, head_dim=64, expand=2,
                       chunk=128),
    shared_attn=AttnConfig(d_model=2560, n_heads=32, n_kv_heads=32,
                           head_dim=80),
    shared_d_ff=10240, shared_every=6, n_shared_blocks=2,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)

PLANS = {
    "train_4k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                             batch_axes=("pod", "data")),
    "prefill_32k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                                batch_axes=("pod", "data")),
    "decode_32k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                               batch_axes=("pod", "data")),
    "long_500k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                              batch_axes=(), seq_shard_axis="data",
                              notes="shared-attn caches seq-sharded"),
}


def batch_struct(shape: ShapeSpec, plan=None):
    return token_batch_struct(shape, CFG.vocab)


def loss_fn(params, batch, rng):
    return zm.zamba2_loss(params, batch, CFG)


def cache_struct(shape: ShapeSpec):
    return jax.eval_shape(
        lambda: zm.init_states(CFG, shape.global_batch, shape.seq_len))


def make_decode_fn(shape: ShapeSpec):
    def decode(params, token, states):
        return zm.decode_step(params, token, states, CFG)
    return decode


def get_bundle():
    return ArchBundle(
        name="zamba2-2.7b", family="hybrid", cfg=CFG,
        init_fn=lambda key: zm.init_zamba2(key, CFG),
        loss_fn=loss_fn, batch_struct=batch_struct, plans=PLANS,
        shape_support={s: "ok" for s in
                       ("train_4k", "prefill_32k", "decode_32k", "long_500k")},
        param_count=CFG.param_count(), active_param_count=CFG.param_count(),
        make_decode_fn=make_decode_fn, cache_struct=cache_struct,
        notes="Mamba2 + shared attention blocks (PULSE collocation case)")
