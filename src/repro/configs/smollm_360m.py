"""smollm-360m [dense]: 32L d=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

llama-arch small model with tied embeddings.  PULSE-relevant: the folded
wave (S=32, 1 layer/stage) collocates stage 0 (embedding) with stage 31
(tied readout) on device 0 — the tied matrix needs no cross-stage gradient
exchange (DESIGN.md §4).
"""
import jax.numpy as jnp
from repro.configs.lm_common import lm_bundle
from repro.models.lm import LMConfig
from repro.models.layers import AttnConfig
from repro.train.steps import ParallelPlan

CFG = LMConfig(
    name="smollm-360m", vocab=49152, d_model=960, n_layers=32,
    attn=AttnConfig(d_model=960, n_heads=15, n_kv_heads=5, head_dim=64),
    d_ff=2560, tied_embeddings=True,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True)

PLANS = {
    "train_4k": ParallelPlan(strategy="pp_wave", pp_degree=16,
                             microbatches=16, batch_axes=("pod", "data"),
                             fsdp_axes=("data",),
                             notes="PULSE wave S=32: tied embed/head fold"),
    "prefill_32k": ParallelPlan(tp_axis="model",
                                custom_rules={"wk": (None, None),
                                              "wv": (None, None)}),
    "decode_32k": ParallelPlan(tp_axis="model",
                               custom_rules={"wk": (None, None),
                                             "wv": (None, None)}),
    "long_500k": ParallelPlan(),
}


def get_bundle():
    return lm_bundle("smollm-360m", CFG, PLANS,
                     notes="wave-fold demo for tied embeddings")
