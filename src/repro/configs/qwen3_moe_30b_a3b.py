"""qwen3-moe-30b-a3b [moe]: 48L d=2048 32H (GQA kv=4, head_dim=128,
qk-norm) expert d_ff=768, 128 experts top-8, vocab=151936.

Fine-grained MoE: expert parallelism over 'model' (8 experts/chip at TP16)
with the sort/scatter dispatch (the one-hot dispatch einsum costs >10x the
expert FLOPs at k=8, f=768 — see EXPERIMENTS.md §Perf).
"""
import jax.numpy as jnp
from repro.configs.lm_common import lm_bundle
from repro.models.lm import LMConfig
from repro.models.layers import AttnConfig, MoEConfig
from repro.train.steps import ParallelPlan

CFG = LMConfig(
    name="qwen3-moe-30b-a3b", vocab=151936, d_model=2048, n_layers=48,
    attn=AttnConfig(d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
                    qk_norm=True),
    moe=MoEConfig(d_model=2048, d_ff=768, n_experts=128, top_k=8,
                  capacity_factor=1.25),
    moe_dispatch="scatter",
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True)

_KV_REP = {"wk": (None, None), "wv": (None, None)}
PLANS = {
    "train_4k": ParallelPlan(tp_axis="model", ep=True, fsdp_axes=("data",),
                             custom_rules=_KV_REP,
                             notes="EP-16 (8 experts/chip) + ZeRO over data"),
    "prefill_32k": ParallelPlan(tp_axis="model", ep=True,
                                custom_rules=_KV_REP),
    "decode_32k": ParallelPlan(tp_axis="model", ep=True,
                               custom_rules=_KV_REP),
    "long_500k": ParallelPlan(),
}


def get_bundle():
    return lm_bundle("qwen3-moe-30b-a3b", CFG, PLANS,
                     notes="128-expert MoE, scatter dispatch, EP-16")
