"""Reduced smoke variants of every assigned architecture family.

Same code paths as the full configs (family, attention flavour, MoE
dispatch, skip structure) at CPU-runnable sizes.  Each entry returns
``(loss_fn, init_fn, make_batch, cfg)`` where ``loss_fn(params, batch, rng)``
is a scalar; tests run one forward/train step and assert finiteness and
output shapes.
"""
from __future__ import annotations

import jax

from repro.models import lm as lm_mod
from repro.models import whisper as wh
from repro.models import xlstm as xm
from repro.models import mamba as zm
from repro.models import diffusion as dm
from repro.models.lm import LMConfig
from repro.models.layers import AttnConfig, MLAConfig, MoEConfig
from repro.models.whisper import WhisperConfig
from repro.models.xlstm import XLSTMConfig
from repro.models.mamba import Zamba2Config, Mamba2Config
from repro.models.diffusion import UViTConfig, HunyuanDiTConfig, UNetConfig


def _lm(cfg: LMConfig, seq: int = 32, batch: int = 2, prefix=None):
    def make_batch(key):
        b = {"tokens": jax.random.randint(key, (batch, seq), 0, cfg.vocab)}
        if prefix:
            b["prefix_embeds"] = jax.random.normal(
                key, (batch, prefix, cfg.d_model), cfg.dtype)
        return b
    return (lambda p, b, r: lm_mod.lm_loss(p, b, cfg),
            lambda k: lm_mod.init_lm(k, cfg), make_batch, cfg)


def smoke_smollm():
    cfg = LMConfig("smollm-smoke", vocab=256, d_model=64, n_layers=4,
                   attn=AttnConfig(64, 4, 2, 16), d_ff=128,
                   tied_embeddings=True)
    return _lm(cfg)


def smoke_danube():
    cfg = LMConfig("danube-smoke", vocab=256, d_model=64, n_layers=4,
                   attn=AttnConfig(64, 4, 2, 16, window=8), d_ff=128)
    return _lm(cfg)


def smoke_internlm2():
    cfg = LMConfig("internlm2-smoke", vocab=256, d_model=64, n_layers=4,
                   attn=AttnConfig(64, 4, 2, 16), d_ff=128)
    return _lm(cfg)


def smoke_granite():
    cfg = LMConfig("granite-smoke", vocab=256, d_model=64, n_layers=6,
                   attn=AttnConfig(64, 4, 1, 16), d_ff=192)   # MQA
    return _lm(cfg)


def smoke_internvl2():
    cfg = LMConfig("internvl2-smoke", vocab=256, d_model=64, n_layers=3,
                   attn=AttnConfig(64, 4, 2, 16), d_ff=128, vision_prefix=8)
    return _lm(cfg, prefix=8)


def smoke_qwen3_moe():
    cfg = LMConfig("qwen3-smoke", vocab=256, d_model=64, n_layers=3,
                   attn=AttnConfig(64, 4, 2, 16, qk_norm=True),
                   moe=MoEConfig(64, 32, n_experts=8, top_k=2,
                                 capacity_factor=2.0),
                   moe_dispatch="scatter")
    return _lm(cfg)


def smoke_deepseek():
    cfg = LMConfig("deepseek-smoke", vocab=256, d_model=64, n_layers=4,
                   mla=MLAConfig(64, 4, q_lora_rank=32, kv_lora_rank=16,
                                 qk_nope_dim=16, qk_rope_dim=8,
                                 v_head_dim=16),
                   d_ff=128,
                   moe=MoEConfig(64, 32, n_experts=4, top_k=2, n_shared=1,
                                 capacity_factor=2.0),
                   moe_dispatch="scatter", n_dense_layers=1, mtp=True)
    return _lm(cfg)


def smoke_whisper():
    cfg = WhisperConfig("whisper-smoke", vocab=256, d_model=32,
                        n_enc_layers=2, n_dec_layers=2, n_heads=4, d_ff=64)

    def make_batch(key):
        return {"frames": jax.random.normal(key, (2, 12, 32)),
                "tokens": jax.random.randint(key, (2, 10), 0, 256)}
    return (lambda p, b, r: wh.whisper_loss(p, b, cfg),
            lambda k: wh.init_whisper(k, cfg), make_batch, cfg)


def smoke_xlstm():
    cfg = XLSTMConfig("xlstm-smoke", vocab=256, d_model=32, n_layers=4,
                      n_heads=2, slstm_every=3)

    def make_batch(key):
        return {"tokens": jax.random.randint(key, (2, 16), 0, 256)}
    return (lambda p, b, r: xm.xlstm_loss(p, b, cfg),
            lambda k: xm.init_xlstm(k, cfg), make_batch, cfg)


def smoke_zamba2():
    cfg = Zamba2Config("zamba2-smoke", vocab=256, d_model=32, n_layers=6,
                       mamba=Mamba2Config(d_model=32, d_state=8, head_dim=8,
                                          chunk=4),
                       shared_attn=AttnConfig(32, 4, 4, 8), shared_d_ff=64,
                       shared_every=3, n_shared_blocks=2)

    def make_batch(key):
        return {"tokens": jax.random.randint(key, (2, 16), 0, 256)}
    return (lambda p, b, r: zm.zamba2_loss(p, b, cfg),
            lambda k: zm.init_zamba2(k, cfg), make_batch, cfg)


def smoke_uvit():
    cfg = UViTConfig("uvit-smoke", img_size=8, in_ch=4, patch=2, d_model=32,
                     n_layers=4, n_heads=4, d_ff=64, n_classes=10)

    def make_batch(key):
        return {"latents": jax.random.normal(key, (2, 8, 8, 4)),
                "labels": jax.random.randint(key, (2,), 0, 10)}
    return (lambda p, b, r: dm.uvit_loss(p, b, r, cfg),
            lambda k: dm.init_uvit(k, cfg), make_batch, cfg)


def smoke_hunyuan():
    cfg = HunyuanDiTConfig("hunyuan-smoke", img_size=8, in_ch=4, patch=2,
                           d_model=32, n_layers=4, n_heads=4, d_ff=64,
                           ctx_dim=16, ctx_len=7)

    def make_batch(key):
        return {"latents": jax.random.normal(key, (2, 8, 8, 4)),
                "text_embeds": jax.random.normal(key, (2, 7, 16))}
    return (lambda p, b, r: dm.hunyuan_loss(p, b, r, cfg),
            lambda k: dm.init_hunyuan(k, cfg), make_batch, cfg)


def smoke_sdv2():
    cfg = UNetConfig("sdv2-smoke", img_size=16, in_ch=4, base_ch=16,
                     ch_mults=(1, 2), blocks_per_level=2, attn_levels=(1,),
                     ctx_dim=16, n_heads=4)

    def make_batch(key):
        return {"latents": jax.random.normal(key, (2, 16, 16, 4)),
                "text_embeds": jax.random.normal(key, (2, 7, 16))}
    return (lambda p, b, r: dm.unet_loss(p, b, r, cfg),
            lambda k: dm.init_unet(k, cfg), make_batch, cfg)


SMOKE_FACTORIES = {
    "smollm-360m": smoke_smollm,
    "h2o-danube-1.8b": smoke_danube,
    "internlm2-20b": smoke_internlm2,
    "granite-34b": smoke_granite,
    "whisper-base": smoke_whisper,
    "xlstm-125m": smoke_xlstm,
    "internvl2-2b": smoke_internvl2,
    "qwen3-moe-30b-a3b": smoke_qwen3_moe,
    "deepseek-v3-671b": smoke_deepseek,
    "zamba2-2.7b": smoke_zamba2,
    "uvit-h": smoke_uvit,
    "sdv2-unet": smoke_sdv2,
    "hunyuan-dit": smoke_hunyuan,
}
