"""granite-34b [dense]: 88L d=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.

88 layers are not divisible by 16 stages, so training is Megatron-style
TP-16 x FSDP over data (PULSE degenerate; the DP partitioner still load-
balances the 88-block graph in benchmarks).  MQA: the single kv head is
replicated across the TP group.
"""
import jax.numpy as jnp
from repro.configs.lm_common import lm_bundle
from repro.models.lm import LMConfig
from repro.models.layers import AttnConfig
from repro.train.steps import ParallelPlan

CFG = LMConfig(
    name="granite-34b", vocab=49152, d_model=6144, n_layers=88,
    attn=AttnConfig(d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128),
    d_ff=24576, mlp_gelu=True,   # gpt_bigcode-style 2-matrix MLP
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True)

_KV_REP = {"wk": (None, None), "wv": (None, None)}
PLANS = {
    "train_4k": ParallelPlan(tp_axis="model", fsdp_axes=("data",),
                             custom_rules=_KV_REP),
    "prefill_32k": ParallelPlan(tp_axis="model", custom_rules=_KV_REP),
    "decode_32k": ParallelPlan(tp_axis="model", custom_rules=_KV_REP),
    "long_500k": ParallelPlan(),
}


def get_bundle():
    return lm_bundle("granite-34b", CFG, PLANS)
