"""ArchBundle: everything the launcher / dry-run / tests need per arch."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.steps import ParallelPlan

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass
class ArchBundle:
    name: str
    family: str
    cfg: Any
    init_fn: Callable                      # key -> params
    loss_fn: Callable                      # (params, batch, rng) -> scalar
    # batch ShapeDtypeStructs for a shape (train/prefill); None if unsupported
    batch_struct: Callable                 # (ShapeSpec, ParallelPlan) -> pytree
    plans: dict[str, ParallelPlan]         # per shape name
    shape_support: dict[str, str]          # shape -> "ok" | skip reason
    param_count: int = 0
    active_param_count: int = 0
    # serving (decode shapes): both optional for train-only archs
    make_decode_fn: Callable | None = None  # (ShapeSpec)->(params,tok,c)->(l,c)
    cache_struct: Callable | None = None    # (ShapeSpec) -> cache pytree struct
    # PULSE pipeline (pp_* strategies)
    make_adapter: Callable | None = None    # (plan, mesh_axis_sizes) -> adapter
    make_microbatches: Callable | None = None
    # reduced-depth variant for roofline probe extrapolation
    scaled_cfg: Callable | None = None      # (n_layers:int) -> ArchBundle
    # reduced smoke config for CPU tests
    smoke: Callable | None = None           # () -> (loss, batch) runnable test
    notes: str = ""

    def supported(self, shape: str) -> bool:
        return self.shape_support.get(shape) == "ok"


def token_batch_struct(shape: ShapeSpec, vocab: int,
                       microbatched: int | None = None) -> Pytree:
    B, S = shape.global_batch, shape.seq_len
    if microbatched:
        M = microbatched
        return {"tokens": jax.ShapeDtypeStruct((M, B // M, S), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
