"""whisper-base [audio]: 6L enc + 6L dec, d=512 8H d_ff=2048 vocab=51865.

Conv frontend is a STUB (precomputed frame embeddings are the input).
seq_len applies to the audio-frame axis; decoder targets are <= 448 tokens
(whisper's max).  Encoder is full attention -> long_500k skipped.
Tiny model: FSDP over 'model' + DP over pod x data.
"""
import jax
import jax.numpy as jnp
from repro.configs.base import ArchBundle, ShapeSpec
from repro.models import whisper as wh
from repro.models.whisper import WhisperConfig
from repro.train.steps import ParallelPlan

CFG = WhisperConfig(
    name="whisper-base", vocab=51865, d_model=512, n_enc_layers=6,
    n_dec_layers=6, n_heads=8, d_ff=2048,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)

MAX_TGT = 448

PLANS = {
    "train_4k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                             batch_axes=("pod", "data")),
    "prefill_32k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                                batch_axes=("pod", "data")),
    "decode_32k": ParallelPlan(tp_axis=None, fsdp_axes=("model",),
                               batch_axes=("pod", "data")),
    "long_500k": ParallelPlan(),
}

SUPPORT = {
    "train_4k": "ok", "prefill_32k": "ok", "decode_32k": "ok",
    "long_500k": "skipped: full-attention audio encoder (1500-frame native "
                 "context); no sub-quadratic path",
}


def batch_struct(shape: ShapeSpec, plan=None):
    B = shape.global_batch
    return {
        "frames": jax.ShapeDtypeStruct((B, shape.seq_len, CFG.d_model),
                                       jnp.bfloat16),
        "tokens": jax.ShapeDtypeStruct((B, MAX_TGT), jnp.int32),
    }


def loss_fn(params, batch, rng):
    return wh.whisper_loss(params, batch, CFG)


def cache_struct(shape: ShapeSpec):
    B = shape.global_batch
    return {
        "enc_out": jax.ShapeDtypeStruct((B, shape.seq_len, CFG.d_model),
                                        jnp.bfloat16),
        "dec": jax.eval_shape(
            lambda: wh.init_dec_caches(CFG, B, MAX_TGT)),
    }


def make_decode_fn(shape: ShapeSpec):
    def decode(params, token, cache):
        logits, dec = wh.decode_step(params, token, cache["enc_out"],
                                     cache["dec"], CFG)
        return logits, {"enc_out": cache["enc_out"], "dec": dec}
    return decode


def get_bundle():
    return ArchBundle(
        name="whisper-base", family="audio", cfg=CFG,
        init_fn=lambda key: wh.init_whisper(key, CFG),
        loss_fn=loss_fn, batch_struct=batch_struct, plans=PLANS,
        shape_support=SUPPORT, param_count=CFG.param_count(),
        active_param_count=CFG.param_count(),
        make_decode_fn=make_decode_fn, cache_struct=cache_struct,
        notes="enc-dec; audio frontend stubbed; decode = cross-attend to "
              "seq_len encoded frames")
