"""Shared bundle builder for the unified LM family."""
from __future__ import annotations

import dataclasses

import jax

from repro.configs.base import ArchBundle, ShapeSpec, SHAPES, token_batch_struct
from repro.models import lm as lm_mod
from repro.models.lm import LMConfig
from repro.runtime.pipeline import PipelineConfig
from repro.runtime.adapters import LMPipelineAdapter
from repro.train.steps import ParallelPlan

FULL_ATTN_SKIP = ("skipped: full quadratic attention — long_500k requires "
                  "sub-quadratic context handling (DESIGN.md §4)")


def lm_bundle(
    name: str,
    cfg: LMConfig,
    plans: dict[str, ParallelPlan],
    *,
    long_ok: bool = False,
    long_reason: str = FULL_ATTN_SKIP,
    vision_prefix_struct=None,
    notes: str = "",
) -> ArchBundle:
    support = {s: "ok" for s in SHAPES}
    if not long_ok:
        support["long_500k"] = long_reason

    def batch_struct(shape: ShapeSpec, plan: ParallelPlan | None = None):
        plan = plan or plans.get(shape.name)
        mb = (plan.microbatches if plan and plan.strategy.startswith("pp")
              else None)
        bs = token_batch_struct(shape, cfg.vocab, microbatched=mb)
        if vision_prefix_struct is not None and shape.kind == "train":
            bs["prefix_embeds"] = vision_prefix_struct(shape, mb)
        return bs

    def loss_fn(params, batch, rng):
        return lm_mod.lm_loss(params, batch, cfg)

    def make_decode_fn(shape: ShapeSpec):
        def decode(params, token, caches):
            return lm_mod.decode_step(params, token, caches, cfg)
        return decode

    def cache_struct(shape: ShapeSpec):
        return jax.eval_shape(
            lambda: lm_mod.init_caches(cfg, shape.global_batch, shape.seq_len,
                                       dtype=cfg.dtype))

    def make_adapter(plan: ParallelPlan, mesh):
        axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_axes = tuple(a for a in plan.batch_axes if a in axis_sizes)
        dp = 1
        for a in dp_axes:
            dp *= axis_sizes[a]
        pcfg = PipelineConfig(
            num_devices=axis_sizes["model"],
            num_microbatches=plan.microbatches,
            data_axes=dp_axes, dp_size=dp, remat=True)
        return LMPipelineAdapter(cfg, pcfg, wave=plan.strategy == "pp_wave")

    def make_microbatches(batch, rng, edge):
        return (batch,)       # batch already arrives microbatch-stacked

    def scaled_cfg(n_layers: int) -> LMConfig:
        n_dense = min(cfg.n_dense_layers, max(n_layers - 1, 0)) \
            if cfg.moe else 0
        return dataclasses.replace(cfg, n_layers=n_layers,
                                   n_dense_layers=n_dense)

    return ArchBundle(
        name=name, family="lm", cfg=cfg,
        init_fn=lambda key: lm_mod.init_lm(key, cfg),
        loss_fn=loss_fn,
        batch_struct=batch_struct,
        plans=plans,
        shape_support=support,
        param_count=cfg.param_count(),
        active_param_count=cfg.active_param_count(),
        make_decode_fn=make_decode_fn,
        cache_struct=cache_struct,
        make_adapter=make_adapter,
        make_microbatches=make_microbatches,
        scaled_cfg=scaled_cfg,
        notes=notes,
    )
