"""internvl2-2b [vlm]: 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.

InternViT frontend is a STUB per the assignment: input_specs provide 256
pre-projected patch embeddings (B, 256, d_model) prepended to the text
tokens; the LM backbone (InternLM2-1.8B-like) is real.
"""
import jax
import jax.numpy as jnp
from repro.configs.lm_common import lm_bundle
from repro.models.lm import LMConfig
from repro.models.layers import AttnConfig
from repro.train.steps import ParallelPlan

N_PATCHES = 256

CFG = LMConfig(
    name="internvl2-2b", vocab=92553, d_model=2048, n_layers=24,
    attn=AttnConfig(d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128),
    d_ff=8192, vision_prefix=N_PATCHES,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True)

_KV_REP = {"wk": (None, None), "wv": (None, None)}
PLANS = {
    "train_4k": ParallelPlan(tp_axis="model", fsdp_axes=("data",),
                             custom_rules=_KV_REP),
    "prefill_32k": ParallelPlan(tp_axis="model", custom_rules=_KV_REP),
    "decode_32k": ParallelPlan(tp_axis="model", custom_rules=_KV_REP),
    "long_500k": ParallelPlan(),
}


def _prefix_struct(shape, mb):
    B = shape.global_batch
    if mb:
        return jax.ShapeDtypeStruct((mb, B // mb, N_PATCHES, CFG.d_model),
                                    jnp.bfloat16)
    return jax.ShapeDtypeStruct((B, N_PATCHES, CFG.d_model), jnp.bfloat16)


def get_bundle():
    return lm_bundle("internvl2-2b", CFG, PLANS,
                     vision_prefix_struct=_prefix_struct,
                     notes="ViT frontend stubbed (patch embeddings input)")
