"""Hunyuan-DiT-3B (paper's own model, scaled): 32 DiT blocks (16+16 with
long skips), d=2048, 16 heads, d_ff=8192, adaLN time conditioning, text
cross-attention (CLIP+T5 stub embeddings), latent 64x64x4.
"""
import jax
import jax.numpy as jnp
from repro.configs.base import ArchBundle, ShapeSpec
from repro.models import diffusion as dm
from repro.models.diffusion import HunyuanDiTConfig
from repro.runtime.pipeline import PipelineConfig
from repro.runtime.adapters import (DiffusionPipelineAdapter,
                                    make_diffusion_microbatches)
from repro.train.steps import ParallelPlan

CFG = HunyuanDiTConfig(
    name="hunyuan-dit", img_size=64, in_ch=4, patch=2, d_model=2048,
    n_layers=32, n_heads=16, d_ff=8192, ctx_dim=1024, ctx_len=77,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)

PLANS = {
    "train_4k": ParallelPlan(strategy="pp_wave", pp_degree=16,
                             microbatches=16, batch_axes=("pod", "data"),
                             fsdp_axes=("data",)),
}
SUPPORT = {"train_4k": "ok",
           "prefill_32k": "n/a: diffusion training arch",
           "decode_32k": "n/a: diffusion training arch",
           "long_500k": "n/a: diffusion training arch"}


def batch_struct(shape: ShapeSpec, plan=None):
    plan = plan or PLANS["train_4k"]
    M = plan.microbatches
    B = shape.global_batch
    return {
        "latents": jax.ShapeDtypeStruct((M, B // M, CFG.img_size,
                                         CFG.img_size, CFG.in_ch),
                                        jnp.bfloat16),
        "text_embeds": jax.ShapeDtypeStruct((M, B // M, CFG.ctx_len,
                                             CFG.ctx_dim), jnp.bfloat16),
    }


def loss_fn(params, batch, rng):
    flat = {k: v.reshape((-1,) + v.shape[2:]) for k, v in batch.items()}
    return dm.hunyuan_loss(params, flat, rng, CFG)


def make_adapter(plan: ParallelPlan, mesh):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in plan.batch_axes if a in axis_sizes)
    dp = 1
    for a in dp_axes:
        dp *= axis_sizes[a]
    pcfg = PipelineConfig(num_devices=axis_sizes["model"],
                          num_microbatches=plan.microbatches,
                          data_axes=dp_axes, dp_size=dp, remat=True)
    return DiffusionPipelineAdapter(CFG, pcfg, "hunyuan")


def make_microbatches(batch, rng, edge):
    M, b = batch["latents"].shape[:2]
    flat = {k: v.reshape((M * b,) + v.shape[2:]) for k, v in batch.items()}
    mb, aux = make_diffusion_microbatches(flat, rng, M, CFG, "hunyuan",
                                          params=edge)
    return (mb, aux)


def pipeline_graph(batch: int = 1, fwd_times=None):
    """Runtime-aligned block graph for the auto-pipeline compile path
    (one block per enc/dec row, fully-paired skips)."""
    return dm.hunyuan_pipeline_graph(CFG, batch, fwd_times=fwd_times)


def pipeline_model_fns():
    """Block-level compile-path callables for this config's model."""
    from repro.runtime.adapters import diffusion_model_fns
    return diffusion_model_fns(CFG, "hunyuan")


def auto_plan(N: int, **kwargs):
    """Plan + lower this config through the full compile path
    (graph -> skip-aware partition -> validated schedule -> executor).

    ``N`` is the total device budget; keyword arguments forward to
    :func:`repro.runtime.compile.auto_pipeline` (e.g. ``pipeline_devices``
    to pin the pipeline degree, ``microbatches``, ``use_ilp``).
    """
    from repro.runtime.compile import auto_pipeline
    return auto_pipeline(pipeline_graph(), pipeline_model_fns(), N, **kwargs)


def get_bundle():
    return ArchBundle(
        name="hunyuan-dit", family="diffusion", cfg=CFG,
        init_fn=lambda key: dm.init_hunyuan(key, CFG),
        loss_fn=loss_fn, batch_struct=batch_struct, plans=PLANS,
        shape_support=SUPPORT, param_count=CFG.param_count(),
        active_param_count=CFG.param_count(),
        make_adapter=make_adapter, make_microbatches=make_microbatches,
        notes="paper model; adaLN + cross-attn wave pipeline")
