"""SDv2-style UNet 4.6B-scale (paper's own model): conv ResNet + attention
blocks at 4 resolutions, base_ch=448, mults (1,2,4,4), CLIP text ctx.

Heterogeneous blocks (paper Fig. 6: ~3x per-block cost spread) — the
skip-aware DP partitioner's showcase (benchmarks/partition_balance.py).
Execution at scale uses GSPMD FSDP; the wave executor demonstrates on the
homogeneous UViT/Hunyuan instead (DESIGN.md §3 heterogeneity note).
"""
import jax
import jax.numpy as jnp
from repro.configs.base import ArchBundle, ShapeSpec
from repro.models import diffusion as dm
from repro.models.diffusion import UNetConfig
from repro.train.steps import ParallelPlan

CFG = UNetConfig(
    name="sdv2-unet", img_size=32, in_ch=4, base_ch=448,
    ch_mults=(1, 2, 4, 4), blocks_per_level=2, attn_levels=(1, 2, 3),
    ctx_dim=1024, ctx_len=77, n_heads=8,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)

PLANS = {
    "train_4k": ParallelPlan(tp_axis=None, fsdp_axes=("model", "data"),
                             batch_axes=("pod", "data")),
}
SUPPORT = {"train_4k": "ok",
           "prefill_32k": "n/a: diffusion training arch",
           "decode_32k": "n/a: diffusion training arch",
           "long_500k": "n/a: diffusion training arch"}


def batch_struct(shape: ShapeSpec, plan=None):
    B = shape.global_batch
    return {
        "latents": jax.ShapeDtypeStruct((B, CFG.img_size, CFG.img_size,
                                         CFG.in_ch), jnp.bfloat16),
        "text_embeds": jax.ShapeDtypeStruct((B, CFG.ctx_len, CFG.ctx_dim),
                                            jnp.bfloat16),
    }


def loss_fn(params, batch, rng):
    return dm.unet_loss(params, batch, rng, CFG)


def get_bundle():
    return ArchBundle(
        name="sdv2-unet", family="diffusion", cfg=CFG,
        init_fn=lambda key: dm.init_unet(key, CFG),
        loss_fn=loss_fn, batch_struct=batch_struct, plans=PLANS,
        shape_support=SUPPORT, param_count=CFG.param_count(),
        active_param_count=CFG.param_count(),
        notes="heterogeneous UNet; partitioner showcase")
