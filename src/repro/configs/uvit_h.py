"""UViT-2.7B (paper's own model, scaled per §VII-B): 32 blocks (16 enc +
16 dec with long skips), d=2560, 20 heads (head_dim 128), d_ff=10240,
latent 32x32x4, class-conditional.

Runs the PULSE wave pipeline end-to-end (S=32 folded stages on 16 devices,
1 block/stage) — the paper's flagship configuration.  Diffusion shapes:
``train_4k`` maps to the paper's latent-diffusion training batch
(latents 32x32x4, global batch 256); LM serve shapes do not apply.
"""
import jax
import jax.numpy as jnp
from repro.configs.base import ArchBundle, ShapeSpec
from repro.models import diffusion as dm
from repro.models.diffusion import UViTConfig
from repro.runtime.pipeline import PipelineConfig
from repro.runtime.adapters import (DiffusionPipelineAdapter,
                                    make_diffusion_microbatches)
from repro.train.steps import ParallelPlan

CFG = UViTConfig(
    name="uvit-h", img_size=32, in_ch=4, patch=2, d_model=2560,
    n_layers=32, n_heads=20, d_ff=10240, n_classes=1001,
    dtype=jnp.bfloat16, param_dtype=jnp.bfloat16)

PLANS = {
    "train_4k": ParallelPlan(strategy="pp_wave", pp_degree=16,
                             microbatches=16, batch_axes=("pod", "data"),
                             fsdp_axes=("data",),
                             notes="paper's wave: S=32 folded, skip-local"),
}
SUPPORT = {"train_4k": "ok",
           "prefill_32k": "n/a: diffusion training arch (no LM serving)",
           "decode_32k": "n/a: diffusion training arch",
           "long_500k": "n/a: diffusion training arch"}


def batch_struct(shape: ShapeSpec, plan=None):
    plan = plan or PLANS["train_4k"]
    M = plan.microbatches
    B = shape.global_batch
    return {
        "latents": jax.ShapeDtypeStruct((M, B // M, CFG.img_size,
                                         CFG.img_size, CFG.in_ch),
                                        jnp.bfloat16),
        "labels": jax.ShapeDtypeStruct((M, B // M), jnp.int32),
    }


def loss_fn(params, batch, rng):
    flat = {k: v.reshape((-1,) + v.shape[2:]) for k, v in batch.items()}
    return dm.uvit_loss(params, flat, rng, CFG)


def make_adapter(plan: ParallelPlan, mesh):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = tuple(a for a in plan.batch_axes if a in axis_sizes)
    dp = 1
    for a in dp_axes:
        dp *= axis_sizes[a]
    pcfg = PipelineConfig(num_devices=axis_sizes["model"],
                          num_microbatches=plan.microbatches,
                          data_axes=dp_axes, dp_size=dp, remat=True)
    return DiffusionPipelineAdapter(CFG, pcfg, "uvit")


def make_microbatches(batch, rng, edge):
    M, b = batch["latents"].shape[:2]
    flat = {"latents": batch["latents"].reshape((M * b,) + batch["latents"].shape[2:]),
            "labels": batch["labels"].reshape(-1)}
    mb, aux = make_diffusion_microbatches(flat, rng, M, CFG, "uvit")
    return (mb, aux)


def get_bundle():
    return ArchBundle(
        name="uvit-h", family="diffusion", cfg=CFG,
        init_fn=lambda key: dm.init_uvit(key, CFG),
        loss_fn=loss_fn, batch_struct=batch_struct, plans=PLANS,
        shape_support=SUPPORT, param_count=CFG.param_count(),
        active_param_count=CFG.param_count(),
        make_adapter=make_adapter, make_microbatches=make_microbatches,
        notes="paper model; wave pipeline flagship")
