"""internlm2-20b [dense]: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.

48 layers / 16 stages = 3 layers per 1F1B stage: the pipeline-parallel
training cell (PULSE degenerate linear case, S=D).  ZeRO-1 optimizer
sharding over 'data' keeps Adam state within HBM.
"""
import jax.numpy as jnp
from repro.configs.lm_common import lm_bundle
from repro.models.lm import LMConfig
from repro.models.layers import AttnConfig
from repro.train.steps import ParallelPlan

CFG = LMConfig(
    name="internlm2-20b", vocab=92544, d_model=6144, n_layers=48,
    attn=AttnConfig(d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128),
    d_ff=16384, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True)

_KV_REP = {"wk": (None, None), "wv": (None, None)}
PLANS = {
    "train_4k": ParallelPlan(strategy="pp_1f1b", pp_degree=16,
                             microbatches=16, batch_axes=("pod", "data"),
                             fsdp_axes=("data",),
                             notes="1F1B S=D=16, 3 layers/stage, ZeRO-1"),
    "prefill_32k": ParallelPlan(tp_axis="model", custom_rules=_KV_REP),
    "decode_32k": ParallelPlan(tp_axis="model", custom_rules=_KV_REP),
    "long_500k": ParallelPlan(),
}


def get_bundle():
    return lm_bundle("internlm2-20b", CFG, PLANS)
