"""Architecture registry: ``get_arch(name)`` -> ArchBundle.

Assigned architectures (10) plus the paper's own diffusion backbones (3).
"""
from __future__ import annotations

import importlib

ASSIGNED = [
    "smollm-360m", "h2o-danube-1.8b", "internlm2-20b", "granite-34b",
    "whisper-base", "xlstm-125m", "internvl2-2b", "qwen3-moe-30b-a3b",
    "deepseek-v3-671b", "zamba2-2.7b",
]
PAPER_ARCHS = ["uvit-h", "sdv2-unet", "hunyuan-dit"]
ALL_ARCHS = ASSIGNED + PAPER_ARCHS

_MODULES = {
    "smollm-360m": "smollm_360m",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "internlm2-20b": "internlm2_20b",
    "granite-34b": "granite_34b",
    "whisper-base": "whisper_base",
    "xlstm-125m": "xlstm_125m",
    "internvl2-2b": "internvl2_2b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "zamba2-2.7b": "zamba2_2_7b",
    "uvit-h": "uvit_h",
    "sdv2-unet": "sdv2_unet",
    "hunyuan-dit": "hunyuan_dit",
}

_cache: dict = {}


def get_arch(name: str):
    if name not in _cache:
        mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
        _cache[name] = mod.get_bundle()
    return _cache[name]


def list_archs() -> list[str]:
    return list(ALL_ARCHS)
