"""h2o-danube-1.8b [dense]: 24L d=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.

llama+mistral mix with sliding-window attention (window 4096) — the SWA
cache is O(window), so long_500k runs (sub-quadratic decode).
24 layers do not divide the 16-wide model axis, so training uses TP+FSDP
(PULSE degenerate case; DESIGN.md §4).
"""
import jax.numpy as jnp
from repro.configs.lm_common import lm_bundle
from repro.models.lm import LMConfig
from repro.models.layers import AttnConfig
from repro.train.steps import ParallelPlan

CFG = LMConfig(
    name="h2o-danube-1.8b", vocab=32000, d_model=2560, n_layers=24,
    attn=AttnConfig(d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
                    window=4096),
    d_ff=6912, dtype=jnp.bfloat16, param_dtype=jnp.bfloat16, remat=True)

_KV_REP = {"wk": (None, None), "wv": (None, None)}   # kv=8 < tp=16
PLANS = {
    "train_4k": ParallelPlan(tp_axis="model", fsdp_axes=("data",),
                             custom_rules=_KV_REP),
    "prefill_32k": ParallelPlan(tp_axis="model", custom_rules=_KV_REP),
    "decode_32k": ParallelPlan(tp_axis="model", custom_rules=_KV_REP),
    "long_500k": ParallelPlan(tp_axis="model", custom_rules=_KV_REP,
                              batch_axes=(), seq_shard_axis="data",
                              notes="window cache seq-sharded over data"),
}


def get_bundle():
    return lm_bundle("h2o-danube-1.8b", CFG, PLANS, long_ok=True,
                     notes="SWA window=4096")
